"""Paper Fig. 1: expectation of BT between two 32-bit numbers with x and y
'1'-bits (Eq. 2), validated against a Monte-Carlo simulation of the
i.i.d.-bit model. Emits corner/center values and the max MC deviation."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import expected_bt_pair
from repro.core.bits import transitions


def _mc_bt(x_ones: int, y_ones: int, n: int = 2000, seed: int = 0) -> float:
    """Monte-Carlo: random 32-bit words with fixed popcounts."""
    rng = np.random.default_rng(seed)
    total = 0
    for _ in range(n):
        a_bits = np.zeros(32, np.uint32)
        a_bits[rng.choice(32, x_ones, replace=False)] = 1
        b_bits = np.zeros(32, np.uint32)
        b_bits[rng.choice(32, y_ones, replace=False)] = 1
        total += int(np.sum(a_bits != b_bits))
    return total / n


def run():
    t0 = time.perf_counter()
    grid = [(0, 0), (0, 32), (32, 32), (16, 16), (8, 24), (4, 4), (28, 30)]
    rows = []
    max_dev = 0.0
    for x, y in grid:
        analytic = float(expected_bt_pair(jnp.asarray(x), jnp.asarray(y), 32))
        mc = _mc_bt(x, y)
        max_dev = max(max_dev, abs(analytic - mc))
        rows.append({"x": x, "y": y, "analytic": analytic, "mc": mc})
    us = (time.perf_counter() - t0) * 1e6
    return rows, max_dev, us


def main(print_csv=True):
    rows, max_dev, us = run()
    if print_csv:
        for r in rows:
            print(f"fig1/E({r['x']},{r['y']}),{us / len(rows):.1f},"
                  f"analytic={r['analytic']:.2f} mc={r['mc']:.2f}")
        print(f"fig1/max_mc_deviation,{us:.1f},dev={max_dev:.3f}")
    return rows


if __name__ == "__main__":
    main()
