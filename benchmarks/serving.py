"""Closed-loop serving suite: steady-state latency/throughput under load.

The offline figures price a transform by its BT; this suite prices the
*service*: back-to-back inferences stream through the mesh under an
offered-load arrival process, each PE's result injection gated on its own
request delivery plus a compute latency (``repro.noc.online``). Per
offered-load point the suite records p50/p99/mean inference latency and
measured throughput; per combo it records the back-to-back saturation
throughput and joins the per-transform BT (O0..O3a) from the offline sweep
rows - by the gating contract the timing axis is transform-independent, so
one gated drain per load point prices the whole transform family.

Since this PR the load axis crosses a *fault-rate* axis
(``repro.noc.faults``): every point re-drains under seeded soft errors
with CRC-8 flit protection and bounded retransmission, under a
per-inference deadline and queue-depth admission control, and reports SLO
attainment + goodput + shed/failed counts alongside p50/p99. The
PR-8 follow-on rides along: a latency SLO curve on trained DarkNet on the
16x16 mesh (packet-subsampled - ``max_packets_per_layer`` below - to keep
the gated fault drains tractable), recorded with the fault-rate column.

Hard assertions (the suite fails rather than record nonsense): every gated
drain conserves its packets, p50 latency is monotonically non-decreasing
along the offered-load axis of every combo, and SLO attainment is
non-increasing along the fault-rate axis (flip schedules are nested in
rate).

``REPRO_BENCH_SMOKE=1`` shrinks to random-init LeNet on 4x4/MC2 with two
load points x two fault rates - the CI gate for the closed-loop path.
"""
from __future__ import annotations

import os

import jax

from repro.data import glyph_batch
from repro.noc import SweepGrid, run_serving

from ._trained import get_trained, random_params

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments")
SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"


def _layers(name: str):
    if SMOKE:
        model, params = random_params(name)
    else:
        model, params, _ = get_trained(name)
    hw, ch = model.input_shape[0], model.input_shape[-1]
    x, _ = glyph_batch(jax.random.PRNGKey(11), 1, hw=hw, channels=ch)
    return model.layer_traffic(params, x[0])


def _grid() -> SweepGrid:
    return SweepGrid(
        meshes=("4x4_mc2",) if SMOKE else ("4x4_mc2", "8x8_mc4"),
        transforms=("O0", "O1", "O2") if SMOKE
        else ("O0", "O1", "O2", "O3", "O3a"),
        tiebreaks=("pattern",),
        precisions=("fixed8",),
        models=("lenet",),
        max_packets_per_layer=12 if SMOKE else 40,
        result_phase=True,
        offered_loads=(2.0, 8.0) if SMOKE else (1.0, 2.0, 4.0, 8.0, 16.0),
        serving_inferences=4 if SMOKE else 16,
        compute_latency=32,
        arrival="uniform",
        chunk=1024,
        fault_rates=(0.0, 1e-3) if SMOKE else (0.0, 1e-3, 5e-3),
        fault_protect="crc8",
        deadline=6000 if SMOKE else 20000,
        admit_queue_depth=6 if SMOKE else 8)


def _darknet_grid() -> SweepGrid:
    """The PR-8 follow-on: trained DarkNet on the 16x16 mesh. Each
    inference's traffic is packet-subsampled (8 packets/layer vs
    darknet_full's complete streams) so the load x fault-rate cross of
    gated retransmission drains stays tractable; the SLO curve's *shape*
    (attainment falling with fault rate, queueing past saturation) is the
    deliverable, not absolute DarkNet cycle counts."""
    return SweepGrid(
        meshes=("16x16_mc16",),
        transforms=("O0", "O1", "O2"),
        tiebreaks=("pattern",),
        precisions=("fixed8",),
        models=("darknet",),
        max_packets_per_layer=8,
        result_phase=True,
        offered_loads=(1.0, 4.0, 16.0),
        serving_inferences=8,
        compute_latency=32,
        arrival="uniform",
        chunk=1024,
        fault_rates=(0.0, 1e-3, 5e-3),
        fault_protect="crc8",
        deadline=20000,
        admit_queue_depth=8)


_POINT_KEYS = ("mesh", "model", "offered_load", "fault_rate", "throughput",
               "p50_latency", "p99_latency", "slo_attainment", "goodput",
               "shed", "failed", "completed", "truncated")
_COMBO_KEYS = ("mesh", "model", "saturation_tput", "latency_monotone",
               "slo_monotone_in_fault", "transforms")


def _run_one(grid: SweepGrid, tag: str, out_name: str) -> dict:
    layers = _layers(grid.models[0])
    layers_fn = lambda _name: layers         # noqa: E731 - one shared load
    report = run_serving(grid, layers_fn,
                         out_path=os.path.join(OUT, out_name),
                         check_conservation=True)
    srv = report.stats["serving"]

    bad = [c for c in srv["combos"] if not c["latency_monotone"]]
    if bad:
        raise AssertionError(
            f"{tag}: p50 latency not monotone in offered load for combos: "
            + ", ".join(f"{c['mesh']}/{c['model']}" for c in bad))
    bad = [c for c in srv["combos"]
           if not c.get("slo_monotone_in_fault", True)]
    if bad:
        raise AssertionError(
            f"{tag}: SLO attainment not monotone in fault rate for combos: "
            + ", ".join(f"{c['mesh']}/{c['model']}" for c in bad))

    for p in srv["points"]:
        gp = p["goodput"]
        tput = p["throughput"]
        print(f"{tag}/{p['mesh']}/{p['model']}/load{p['offered_load']:g}"
              f"/rate{p['fault_rate']:g},{p['p50_latency']},"
              f"p99={p['p99_latency']} "
              f"tput={tput if tput is None else round(tput, 2)} "
              f"slo={p['slo_attainment']} "
              f"goodput={gp if gp is None else round(gp, 2)} "
              f"shed={p['shed']} failed={p['failed']}")
    for c in srv["combos"]:
        print(f"{tag}/{c['mesh']}/{c['model']}/saturation,"
              f"{c['saturation_tput']:.2f},"
              f"monotone={c['latency_monotone']} "
              f"slo_monotone={c.get('slo_monotone_in_fault')}")
    return srv


def main() -> dict:
    srv = _run_one(_grid(), "serving", "serving.json")
    dk = None
    if not SMOKE:
        dk = _run_one(_darknet_grid(), "serving", "serving_darknet.json")

    def _bench(s):
        return {
            "offered_loads": s["offered_loads"],
            "fault_rates": s["fault_rates"],
            "fault_protect": s["fault_protect"],
            "deadline": s["deadline"],
            "admit_queue_depth": s["admit_queue_depth"],
            "inferences": s["inferences"],
            "compute_latency": s["compute_latency"],
            "arrival": s["arrival"],
            "conservation_checked": s["conservation_checked"],
            "points": [{k: p.get(k) for k in _POINT_KEYS}
                       for p in s["points"]],
            "combos": [{k: c.get(k) for k in _COMBO_KEYS}
                       for c in s["combos"]],
            "serving_s": s["serving_s"],
        }

    bench = _bench(srv)
    if dk is not None:
        bench["darknet_16x16"] = _bench(dk)
    return {"results": srv, "bench": bench}


if __name__ == "__main__":
    main()
