"""Closed-loop serving suite: steady-state latency/throughput under load.

The offline figures price a transform by its BT; this suite prices the
*service*: back-to-back inferences stream through the mesh under an
offered-load arrival process, each PE's result injection gated on its own
request delivery plus a compute latency (``repro.noc.online``). Per
offered-load point the suite records p50/p99/mean inference latency and
measured throughput; per combo it records the back-to-back saturation
throughput and joins the per-transform BT (O0..O3a) from the offline sweep
rows - by the gating contract the timing axis is transform-independent, so
one gated drain per load point prices the whole transform family.

Hard assertions (the suite fails rather than record nonsense): every gated
drain conserves its packets, and p50 latency is monotonically
non-decreasing along the offered-load axis of every combo.

``REPRO_BENCH_SMOKE=1`` shrinks to random-init LeNet on 4x4/MC2 with two
load points - the CI gate for the closed-loop path.
"""
from __future__ import annotations

import os

import jax

from repro.data import glyph_batch
from repro.noc import SweepGrid, run_serving

from ._trained import get_trained, random_params

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments")
SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"


def _layers(name: str):
    if SMOKE:
        model, params = random_params(name)
    else:
        model, params, _ = get_trained(name)
    hw, ch = model.input_shape[0], model.input_shape[-1]
    x, _ = glyph_batch(jax.random.PRNGKey(11), 1, hw=hw, channels=ch)
    return model.layer_traffic(params, x[0])


def _grid() -> SweepGrid:
    return SweepGrid(
        meshes=("4x4_mc2",) if SMOKE else ("4x4_mc2", "8x8_mc4"),
        transforms=("O0", "O1", "O2") if SMOKE
        else ("O0", "O1", "O2", "O3", "O3a"),
        tiebreaks=("pattern",),
        precisions=("fixed8",),
        models=("lenet",),
        max_packets_per_layer=12 if SMOKE else 40,
        result_phase=True,
        offered_loads=(2.0, 8.0) if SMOKE else (1.0, 2.0, 4.0, 8.0, 16.0),
        serving_inferences=4 if SMOKE else 16,
        compute_latency=32,
        arrival="uniform",
        chunk=1024)


def main() -> dict:
    grid = _grid()
    layers = _layers(grid.models[0])
    layers_fn = lambda _name: layers         # noqa: E731 - one shared load

    out_path = os.path.join(OUT, "serving.json")
    report = run_serving(grid, layers_fn, out_path=out_path,
                         check_conservation=True)
    srv = report.stats["serving"]

    bad = [c for c in srv["combos"] if not c["latency_monotone"]]
    if bad:
        raise AssertionError(
            "p50 latency not monotone in offered load for combos: "
            + ", ".join(f"{c['mesh']}/{c['model']}" for c in bad))

    for p in srv["points"]:
        print(f"serving/{p['mesh']}/{p['model']}/load{p['offered_load']:g},"
              f"{p['p50_latency']},p99={p['p99_latency']} "
              f"tput={p['throughput']:.2f}")
    for c in srv["combos"]:
        print(f"serving/{c['mesh']}/{c['model']}/saturation,"
              f"{c['saturation_tput']:.2f},"
              f"monotone={c['latency_monotone']}")

    bench = {
        "offered_loads": srv["offered_loads"],
        "inferences": srv["inferences"],
        "compute_latency": srv["compute_latency"],
        "arrival": srv["arrival"],
        "conservation_checked": srv["conservation_checked"],
        "points": [
            {k: p[k] for k in ("mesh", "model", "offered_load",
                               "throughput", "p50_latency", "p99_latency",
                               "completed", "truncated")}
            for p in srv["points"]],
        "combos": [
            {k: c[k] for k in ("mesh", "model", "saturation_tput",
                               "latency_monotone", "transforms")}
            for c in srv["combos"]],
        "serving_s": srv["serving_s"],
    }
    return {"results": srv, "bench": bench}


if __name__ == "__main__":
    main()
