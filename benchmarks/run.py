"""Benchmark suite entry point - one module per paper table/figure plus the
framework-level analyses. Prints ``name,us_per_call,derived`` CSV lines.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run table1 fig12
"""
from __future__ import annotations

import sys
import traceback

from . import (table1, fig1_expectation, fig10_11, fig12, fig13,
               table2_power, ordered_collectives, ordering_throughput,
               roofline)

SUITES = {
    "table1": table1.main,                    # Tab. I: BT reduction w/o NoC
    "fig1": fig1_expectation.main,            # Fig. 1: E[BT] surface
    "fig10_11": fig10_11.main,                # Figs. 10-11: bit distributions
    "fig12": fig12.main,                      # Fig. 12: NoC sizes x O0/O1/O2
    "fig13": fig13.main,                      # Fig. 13: LeNet vs DarkNet
    "table2": table2_power.main,              # Tab. II + link power model
    "ordered_collectives": ordered_collectives.main,  # beyond-paper: ICI
    "ordering_throughput": ordering_throughput.main,
    "roofline": roofline.main,                # from dry-run artifacts
}


def main() -> None:
    picks = sys.argv[1:] or list(SUITES)
    failed = []
    for name in picks:
        try:
            SUITES[name]()
        except Exception as e:  # noqa: BLE001
            failed.append(name)
            print(f"{name},0,FAILED:{type(e).__name__}:{e}")
            traceback.print_exc()
    if failed:
        raise SystemExit(f"failed suites: {failed}")


if __name__ == "__main__":
    main()
