"""Benchmark suite entry point - one module per paper table/figure plus the
framework-level analyses. Prints ``name,us_per_call,derived`` CSV lines.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run table1 fig12
    PYTHONPATH=src python -m benchmarks.run fig12 --transforms O0,O1,O2,O3

After each invocation the NoC-relevant trajectory numbers (per-suite
wall-clock, sweep-engine cycles/sec and packetizer time, result-phase and
affinity deltas, and the pinned speedup-vs-seed-driver comparison) are
written to ``BENCH_noc.json`` at the repo root so future PRs can track
sweep-engine performance. Every suite key and field is documented in
``docs/bench_schema.md``.
"""
from __future__ import annotations

import json
import os
import sys
import time
import traceback

from . import (table1, compression, fig1_expectation, fig10_11, fig12,
               fig13, table2_power, darknet_full, faults, kernel_backend,
               ordered_collectives, ordering_throughput, roofline,
               serving, static_layout, step_overhaul)

SUITES = {
    "table1": table1.main,                    # Tab. I: BT reduction w/o NoC
    "fig1": fig1_expectation.main,            # Fig. 1: E[BT] surface
    "fig10_11": fig10_11.main,                # Figs. 10-11: bit distributions
    "fig12": fig12.main,                      # Fig. 12: NoC sizes x O0/O1/O2
    "fig13": fig13.main,                      # Fig. 13: LeNet vs DarkNet
    "table2": table2_power.main,              # Tab. II + link power model
    "darknet_full": darknet_full.main,        # beyond-paper: full traffic,
                                              # 16x16, placements, sharding
    "step_overhaul": step_overhaul.main,      # fused-step before/after cps
    "kernel_backend": kernel_backend.main,    # Pallas step + batched-O3
                                              # ordering before/after
    "ordered_collectives": ordered_collectives.main,  # beyond-paper: ICI
    "ordering_throughput": ordering_throughput.main,
    "roofline": roofline.main,                # from dry-run artifacts
    "static_layout": static_layout.main,      # trained-vs-random layouts
    "serving": serving.main,                  # closed-loop: latency vs load
    "faults": faults.main,                    # fault injection: BT + SLO
                                              # under flips/dead links
    "compression": compression.main,          # ordering x MSR co-design:
                                              # does ordering pay on 5b lanes
}

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_noc.json")


def main() -> None:
    argv = sys.argv[1:]
    transforms = None
    if "--transforms" in argv:
        i = argv.index("--transforms")
        transforms = tuple(t.strip() for t in argv[i + 1].split(",") if t.strip())
        argv = argv[:i] + argv[i + 2:]
    picks = argv or list(SUITES)
    failed = []
    bench = {"suites": {}}
    # The pinned speedup comparison runs first, while the process is cold:
    # both the seed driver and the sweep engine pay their own compiles.
    if "fig12" in picks:
        try:
            bench["reference_compare"] = fig12.reference_compare()
            rc = bench["reference_compare"]
            print(f"fig12/reference_compare,{rc['sweep_s'] * 1e6:.0f},"
                  f"speedup={rc['speedup']}x bt_identical={rc['bt_identical']}")
        except Exception as e:  # noqa: BLE001
            failed.append("fig12:reference_compare")
            print(f"fig12:reference_compare,0,FAILED:{type(e).__name__}:{e}")
            traceback.print_exc()
    for name in picks:
        try:
            t0 = time.perf_counter()
            # `--transforms O0,O1,O2,O3` widens the ordering axis of the
            # sweep-driven figure suites (e.g. to include the O3 lanes and
            # record the o3_vs_o2 verdict); others keep their defaults.
            if transforms and name in ("fig12", "fig13"):
                out = SUITES[name](transforms=transforms)
            else:
                out = SUITES[name]()
            entry = {"wall_s": round(time.perf_counter() - t0, 3)}
            # Sweep-driven suites return {"results", "bench"}; record the
            # engine stats (cycles/sec simulated, packetizer wall-clock, ...)
            if isinstance(out, dict) and "bench" in out:
                entry.update(out["bench"])
            bench["suites"][name] = entry
        except Exception as e:  # noqa: BLE001
            failed.append(name)
            print(f"{name},0,FAILED:{type(e).__name__}:{e}")
            traceback.print_exc()
    # Merge into the existing trajectory file: a selective run (e.g.
    # `benchmarks.run table1`) must not wipe recorded sweep stats.
    merged = {"suites": {}}
    if os.path.exists(BENCH_PATH):
        try:
            with open(BENCH_PATH) as f:
                merged = json.load(f)
        except (OSError, ValueError):
            pass
    merged.setdefault("suites", {}).update(bench["suites"])
    if "reference_compare" in bench:
        merged["reference_compare"] = bench["reference_compare"]
    # The cross-PR step trajectory: *derived* numbers only - the raw
    # pinned-chunk record lives solely under suites/step_overhaul (it used
    # to be duplicated wholesale at top level; see docs/bench_schema.md).
    dk = merged["suites"].get("darknet_full", {})
    if dk.get("cycles_per_sec"):
        merged["step_trajectory"] = {
            "darknet_full_cps_pr3": step_overhaul.PR3_DARKNET_CPS,
            "darknet_full_cps": dk["cycles_per_sec"],
            "darknet_full_speedup": round(
                dk["cycles_per_sec"] / step_overhaul.PR3_DARKNET_CPS, 2),
        }
    merged.pop("step_overhaul", None)   # drop the pre-PR-7 duplicate block
    # Atomic write: a crash mid-dump must not truncate the trajectory file
    # (the merge above would then silently drop every prior suite's stats).
    tmp = BENCH_PATH + ".tmp"
    with open(tmp, "w") as f:
        json.dump(merged, f, indent=1)
    os.replace(tmp, BENCH_PATH)
    if failed:
        raise SystemExit(f"failed suites: {failed}")


if __name__ == "__main__":
    main()
