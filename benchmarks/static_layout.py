"""Static popcount layout (`dist.static_reorder`) on *converged* weights.

The ROADMAP open item this closes: `stream_bt_report` shows ~0 reduction on
random-init weights, and the open question was whether the popcount
structure of *trained* nets changes that. This suite measures the layout on
the converged LeNet checkpoint (`experiments/weights/lenet/step_000000400`,
restored - never random init) against the random-init baseline and records
the trained-vs-random delta in BENCH_noc.json.

Measured answer (recorded, not assumed): ~0 for the trained checkpoint
too. Unit-order permutation only changes which unit *boundaries* abut on
the wire; the BT of a unit-major stream is dominated by within-unit
word-to-word toggles, which no unit reorder can touch. The structure
trained weights do have is harvested by the in-flight per-packet orderings
(the NoC sweeps and the ordered-collectives path), not by this static
whole-unit layout - BENCH_noc.json keeps both numbers side by side.

The measured block is LeNet's fc1 hidden-unit block: permuting f1w columns
together with f2w rows is the similarity transform of
`dist.static_reorder.reorder_mlp` (a deployment also permutes the f1 bias,
which does not travel on the weight stream being measured).
"""
from __future__ import annotations

from repro.dist.static_reorder import reorder_lm_params, stream_bt_report

from ._trained import get_trained, random_params


def _fc_blocks(params) -> dict:
    """LeNet's fc1 unit block in reorder_mlp's {"wu", "wd"} layout:
    f1w (400, 120) columns and f2w (120, 84) rows are the 120 hidden
    units' wire footprint."""
    return {"fc1": {"wu": params["f1w"], "wd": params["f2w"]}}


def _measure(params) -> dict:
    blocks = _fc_blocks(params)
    rep = stream_bt_report(blocks, reorder_lm_params(blocks))
    return {k: float(v) for k, v in rep.items()}   # jax scalars -> JSON


def run() -> dict:
    _, trained_params, acc = get_trained("lenet")
    _, random_init = random_params("lenet")
    trained = _measure(trained_params)
    random_rep = _measure(random_init)
    return {
        "checkpoint": "experiments/weights/lenet/step_000000400",
        "checkpoint_acc": acc,
        "trained": trained,
        "random_init": random_rep,
        "trained_minus_random_reduction": (
            trained["reduction"] - random_rep["reduction"]),
    }


def main(print_csv=True):
    r = run()
    if print_csv:
        t, rnd = r["trained"], r["random_init"]
        print(f"static_layout/trained,0,"
              f"bt_per_flit {t['bt_per_flit_before']:.2f}->"
              f"{t['bt_per_flit_after']:.2f} "
              f"reduction={t['reduction'] * 100:.2f}%")
        print(f"static_layout/random_init,0,"
              f"reduction={rnd['reduction'] * 100:.2f}%")
        print(f"static_layout/delta,0,trained-random="
              f"{r['trained_minus_random_reduction'] * 100:.2f}pp")
    return {"results": r, "bench": r}


if __name__ == "__main__":
    main()
