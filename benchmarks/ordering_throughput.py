"""Ordering-unit software throughput: the jitted XLA path and the Pallas
kernel path (interpret mode on CPU - correctness harness, not TPU perf).

Derived column reports values/second through the full O2 pipeline
(popcount -> windowed sort -> pack) - the number a deployment compares
against the memory-controller line rate.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import descending_order, pack
from repro.kernels import popcount as pc_kernel, sort_windows_desc, on_tpu


def _time(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run(n=1 << 18, window=512):
    key = jax.random.PRNGKey(0)
    vals = jax.random.normal(key, (n,), jnp.float32)

    @jax.jit
    def xla_o2(v):
        o = descending_order(v, window=window)
        return pack(o.values, 16).words

    us_xla = _time(xla_o2, vals)

    keys = jax.random.randint(key, (n // window, window), 0, 33, jnp.int32)
    payload = jax.random.randint(key, (n // window, window), 0, 2**31 - 1,
                                 jnp.int32).astype(jnp.uint32)
    us_pallas_sort = _time(lambda k, p: sort_windows_desc(k, p)[0],
                           keys, payload)
    us_pallas_pc = _time(pc_kernel, vals)
    return {
        "n": n,
        "xla_o2_us": us_xla,
        "xla_o2_values_per_s": n / (us_xla / 1e6),
        "pallas_sort_us_interpret": us_pallas_sort,
        "pallas_popcount_us_interpret": us_pallas_pc,
        "backend": "tpu" if on_tpu() else "cpu-interpret",
    }


def main(print_csv=True):
    r = run()
    if print_csv:
        print(f"ordering_throughput/xla_o2,{r['xla_o2_us']:.0f},"
              f"{r['xla_o2_values_per_s']:.3g} values/s (n={r['n']})")
        print(f"ordering_throughput/pallas_sort,{r['pallas_sort_us_interpret']:.0f},"
              f"mode={r['backend']}")
        print(f"ordering_throughput/pallas_popcount,"
              f"{r['pallas_popcount_us_interpret']:.0f},mode={r['backend']}")
    return r


if __name__ == "__main__":
    main()
